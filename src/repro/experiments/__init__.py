"""Declarative experiment subsystem (see ISSUE 2 / ROADMAP).

- ``scenario``  — the :class:`Scenario` spec: protocol, N, PigConfig,
  topology, workload, fault plan (``repro.faults``), audit flag, client
  grid, seeds — pure data.
- ``registry``  — name -> scenario, with ``--filter`` glob selection.
- ``catalog``   — every paper reproduction (table1/2, fig8-17) plus the
  post-paper ``zipf``/``openloop``/``conflict``/``wan``/``scale`` and
  fault-injection ``avail``/``storm`` families as registry entries.
- ``runner``    — process-parallel execution over (scenario, clients, seed)
  units; one stable JSON artifact schema with per-seed replicates.
  ``backend="batch"`` scenarios run their whole grid as ONE jitted call on
  ``repro.core.vectorsim`` instead of entering the pool; fault plans are
  compiled per engine and audited units carry consistency verdicts.
- ``report``    — artifact -> the legacy ``name,us_per_call,derived`` rows
  that ``benchmarks/run.py`` prints (perf-trajectory contract).
- ``plot``      — artifact -> throughput-vs-load / latency-CDF SVGs
  (dependency-free; ``benchmarks/run.py --plot DIR``).
"""
from . import registry  # noqa: F401
from .registry import get, names, families, register, select  # noqa: F401
from .runner import ARTIFACT_SCHEMA, run_families, run_scenarios  # noqa: F401
from .scenario import Scenario, build_topology  # noqa: F401
from . import plot  # noqa: F401
from . import report  # noqa: F401
