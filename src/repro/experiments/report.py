"""Artifact -> legacy benchmark rows.

``benchmarks/run.py`` (and the thin per-figure modules kept for ``--only``)
print ``name,us_per_call,derived`` CSV rows; successive PRs diff those rows
to track the perf trajectory.  This module maps the runner's JSON artifact
back onto exactly those row names, and carries each figure's paper-claim
summary (best-R comparison, saturation ratios, analytical-table validation,
failure-transient drop, ...).

Every summarizer degrades gracefully when ``--filter`` removed part of its
family: rows are emitted for whatever scenarios ran, and cross-scenario
summary rows are skipped when their inputs are missing.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Sequence

from repro.core import analytical

from . import registry, runner


def csv_row(name: str, wall_s: float, calls: int, derived: str) -> str:
    us = wall_s * 1e6 / max(calls, 1)
    return f"{name},{us:.1f},{derived}"


def ms(x) -> float:
    """None (no completions in the window) -> nan, so rows degrade to
    'median=nanms' instead of a TypeError killing the whole family."""
    return float("nan") if x is None else x


def _rep(art: dict) -> Optional[dict]:
    """The representative replicate of a max-mode scenario (single-seed
    scenarios: the best-over-grid unit; multi-seed: highest-throughput)."""
    reps = art.get("replicates") or []
    if not reps:
        return None
    return max(reps, key=lambda u: u["throughput"] or 0.0)


def _wall(art: dict) -> float:
    return art["summary"]["wall_s"]


def _tput(art: dict) -> float:
    return art["summary"]["throughput"]["mean"] or 0.0


def _sat(art: dict) -> float:
    """Saturation of a curve-mode scenario: best per-point mean throughput."""
    pts = art.get("points") or []
    return max((p["throughput"]["mean"] or 0.0 for p in pts), default=0.0)


def _point_rows(art: dict, fmt) -> List[str]:
    """One row per client-grid point of a curve-mode scenario; single-seed
    points print the raw unit values (trajectory-stable), multi-seed points
    print across-seed means."""
    out = []
    units_by_clients: Dict[int, List[dict]] = {}
    for u in art["units"]:
        units_by_clients.setdefault(u["clients"], []).append(u)
    for p in art.get("points", []):
        us = units_by_clients.get(p["clients"], [])
        wall = sum(u["wall_s"] for u in us)
        count = sum(u["count"] for u in us)
        out.append(fmt(p, us, wall, count))
    return out


# ------------------------------------------------------------------ tables
def _table_rows(arts: Dict[str, dict], n: int, family: str,
                tol: float = 0.2) -> List[str]:
    rows = analytical.load_table(n)
    wall = sum(_wall(a) for a in arts.values())
    # validate the analytic table against DES-measured per-node counts for
    # every representative R that actually ran
    for name, art in arts.items():
        r = int(name.rsplit("=", 1)[1])
        rep = _rep(art)
        if rep is None or "extras" not in rep:
            continue
        ana = next(x for x in rows if x["R"] == r)
        ml = rep["extras"]["leader_msgs_per_op"]
        mf = rep["extras"]["follower_msgs_per_op"]
        assert abs(ml - ana["M_l"]) < tol, (name, ml, ana)
        assert abs(mf - ana["M_f"]) < tol, (name, mf, ana)
    return [csv_row(f"{family}/R={x['R']}", wall, 1,
                    f"M_l={x['M_l']} M_f={x['M_f']} ratio={x['ratio']}")
            for x in rows]


def _table1(arts, quick):
    return _table_rows(arts, 25, "table1")


def _table2(arts, quick):
    return _table_rows(arts, 5, "table2")


# ------------------------------------------------------------------- fig 8
def _fig8(arts, quick):
    out = []
    results = {}
    for name, art in arts.items():
        rep = _rep(art)
        if rep is None:
            continue
        if name.startswith("fig8/scale/"):
            out.append(csv_row(name, _wall(art), rep["count"],
                               f"tput={rep['throughput']:.0f}req/s "
                               f"median={ms(rep['median_ms']):.2f}ms"))
        else:
            _, label, rtag = name.split("/")
            results[(label, int(rtag[2:]))] = rep["throughput"]
            out.append(csv_row(name, _wall(art), rep["count"],
                               f"tput={rep['throughput']:.0f}req/s "
                               f"median={ms(rep['median_ms']):.2f}ms"))
    rot = {r: t for (lbl, r), t in results.items() if lbl == "rotating"}
    stat = {r: t for (lbl, r), t in results.items() if lbl == "static"}
    if rot and stat:
        out.append(csv_row(
            "fig8/summary", 0, 1,
            f"best_R_rotating={max(rot, key=rot.get)} "
            f"best_R_static={max(stat, key=stat.get)} "
            f"(paper: 1 and ~sqrt(N)=5)"))
    return out


# ------------------------------------------------------------------- fig 9
def _fig9(arts, quick):
    out = []
    sat = {}
    for name, art in arts.items():
        proto = name.split("/")[1]
        def fmt(p, us, wall, count, proto=proto):
            return csv_row(f"fig9/{proto}/clients={p['clients']}", wall, count,
                           f"tput={ms(p['throughput']['mean']):.0f}req/s "
                           f"median={ms(p['median_ms']['mean']):.2f}ms "
                           f"p99={ms(p['p99_ms']['mean']):.2f}ms")
        out.extend(_point_rows(art, fmt))
        sat[proto] = _sat(art)
    if {"paxos", "epaxos", "pigpaxos"} <= set(sat):
        ratio = sat["pigpaxos"] / max(sat["paxos"], 1)
        try:
            from repro.core.jaxsim import saturation_point
            model = f"{saturation_point(25, 24, protocol='paxos'):.0f}"
        except Exception:   # noqa: BLE001  (jax optional for the model row)
            model = "n/a"
        out.append(csv_row(
            "fig9/summary", 0, 1,
            f"paxos={sat['paxos']:.0f} epaxos={sat['epaxos']:.0f} "
            f"pigpaxos={sat['pigpaxos']:.0f} pig/paxos={ratio:.1f}x "
            f"(paper >3x); queueing-model paxos={model}"))
    return out


# ------------------------------------------------------------------ fig 10
def _fig10(arts, quick):
    out = []
    for name, art in arts.items():
        proto = name.split("/")[1]
        def fmt(p, us, wall, count, proto=proto):
            return csv_row(f"fig10/{proto}/clients={p['clients']}", wall, count,
                           f"tput={ms(p['throughput']['mean']):.0f}req/s "
                           f"median={ms(p['median_ms']['mean']):.1f}ms")
        out.extend(_point_rows(art, fmt))
    return out


# ------------------------------------------------------------- figs 11/12
def _bar_family(arts, family, summary):
    out = []
    res = {}
    for name, art in arts.items():
        rep = _rep(art)
        if rep is None:
            continue
        res[name.split("/")[1]] = rep["throughput"]
        out.append(csv_row(name, _wall(art), rep["count"],
                           f"tput={rep['throughput']:.0f}req/s "
                           f"median={ms(rep['median_ms']):.2f}ms"))
    s = summary(res)
    if s:
        out.append(csv_row(f"{family}/summary", 0, 1, s))
    return out


def _fig11(arts, quick):
    def summary(res):
        if "pig_R1" not in res or len(res) < 4:
            return None
        return (f"R1_beats_all={res['pig_R1'] >= max(res.values()) - 1} "
                f"(paper: R=1 outperforms all at N=5)")
    return _bar_family(arts, "fig11", summary)


def _fig12(arts, quick):
    def summary(res):
        if "pig_R2" not in res or "paxos" not in res:
            return None
        gain = (res["pig_R2"] / res["paxos"] - 1) * 100
        return f"R2_gain_over_paxos={gain:.0f}% (paper: ~57%)"
    return _bar_family(arts, "fig12", summary)


# ------------------------------------------------------------------ fig 13
def _fig13(arts, quick):
    out = []
    tputs: Dict[str, Dict[int, float]] = {}
    for name, art in arts.items():
        rep = _rep(art)
        if rep is None:
            continue
        _, proto, stag = name.split("/")
        size = int(stag.split("=")[1])
        tputs.setdefault(proto, {})[size] = rep["throughput"]
        out.append(csv_row(name, _wall(art), rep["count"],
                           f"tput={rep['throughput']:.0f}req/s"))
    for proto, by_size in tputs.items():
        mx = max(by_size.values())
        for s in sorted(by_size):
            out.append(csv_row(f"fig13/{proto}/norm/payload={s}", 0, 1,
                               f"normalized={by_size[s]/mx:.3f} (paper: >0.86)"))
    if "paxos" in tputs and "pigpaxos" in tputs:
        shared = set(tputs["paxos"]) & set(tputs["pigpaxos"])
        if shared:
            r = min(tputs["pigpaxos"][s] / tputs["paxos"][s] for s in shared)
            out.append(csv_row("fig13/summary", 0, 1,
                               f"min_pig_over_paxos={r:.1f}x "
                               f"(paper: ~3x at all sizes)"))
    return out


# ------------------------------------------------------------- figs 14/15
def _iqr_row(name, art):
    rep = _rep(art)
    if rep is None:
        return None
    return csv_row(name, _wall(art), rep["count"],
                   f"median={ms(rep['median_ms']):.2f}ms "
                   f"IQR=[{ms(rep['p25_ms']):.2f},{ms(rep['p75_ms']):.2f}]ms")


def _fig14(arts, quick):
    return [r for name, art in arts.items()
            if (r := _iqr_row(name, art)) is not None]


def _fig15(arts, quick):
    out = []
    base = None
    for name, art in arts.items():
        if name == "fig15/fault_free":
            continue
        rep = _rep(art)
        if rep is None:
            continue
        out.append(csv_row(name, _wall(art), rep["count"],
                           f"median={ms(rep['median_ms']):.2f}ms "
                           f"IQR=[{ms(rep['p25_ms']):.2f},{ms(rep['p75_ms']):.2f}]ms "
                           f"tput={rep['throughput']:.0f}"))
        if name == "fig15/PRC=1/gray=1":
            base = rep["median_ms"]
    ff = arts.get("fig15/fault_free")
    rep0 = _rep(ff) if ff else None
    if rep0 is not None:
        gap = (f"; prc+gray within "
               f"{abs(ms(base) - ms(rep0['median_ms'])):.2f}ms "
               f"of fault-free" if base is not None else "")
        out.append(csv_row("fig15/fault_free", _wall(ff), rep0["count"],
                           f"median={ms(rep0['median_ms']):.2f}ms{gap}"))
    return out


# ------------------------------------------------------------------ fig 16
def _fig16(arts, quick):
    art = arts.get("fig16/group_failure")
    rep = _rep(art) if art else None
    if rep is None or "extras" not in rep:
        return []
    sc = registry.get("fig16/group_failure")
    fail_at = min(float(ev[2]) for ev in sc.fault_plan().events
                  if ev[0] == "crash")
    warmup = rep["warmup_s"]
    tl = rep["extras"]["timeline"]
    b = tl["bucket_s"]
    counts = tl["counts"]
    # round(): 0.3/0.05 is 5.999... in floats; int() would leak a warmup
    # bucket into the pre-failure window
    pre = sum(counts[round(warmup / b):round(fail_at / b)])
    post = sum(counts[round(fail_at / b):round((fail_at + 0.5) / b)])
    tput_pre = pre / (fail_at - warmup)
    tput_post = post / 0.5
    drop = (1 - tput_post / max(tput_pre, 1)) * 100
    return [csv_row("fig16/group_failure", _wall(art), rep["count"],
                    f"tput_before={tput_pre:.0f} tput_during={tput_post:.0f} "
                    f"drop={drop:.1f}% (paper: ~3%)")]


# ------------------------------------------------------------------ fig 17
def _fig17(arts, quick):
    out = []
    mats = {}
    for name, art in arts.items():
        rep = _rep(art)
        if rep is None or "extras" not in rep:
            continue
        proto = name.split("/")[1]
        m = rep["extras"]["flight_per_op"]
        mats[proto] = m
        total = sum(sum(r) for r in m)
        leader = sum(m[0]) + sum(r[0] for r in m)
        mx = max(v for r in m for v in r)
        out.append(csv_row(name, _wall(art), rep["count"],
                           f"leader_traffic_share={leader/max(total, 1e-9):.2f} "
                           f"max_cell={mx:.2f}msg/op"))
    if mats:
        os.makedirs("artifacts", exist_ok=True)
        with open("artifacts/fig17_heatmap.json", "w") as f:
            json.dump(mats, f)
        out.append(csv_row("fig17/summary", 0, 1,
                           "pigpaxos spreads load: see "
                           "artifacts/fig17_heatmap.json"))
    return out


# ----------------------------------------------------- post-paper families
def _mean_std_row(name, art):
    s = art["summary"]
    t = s["throughput"]
    rep = _rep(art)
    if rep is None:
        return None
    return csv_row(name, _wall(art), rep["count"],
                   f"tput={ms(t['mean']):.0f}req/s std={t['std'] or 0:.0f} "
                   f"seeds={t['n']} median={ms(s['median_ms']['mean']):.2f}ms")


def _zipf(arts, quick):
    out = [r for name, art in sorted(arts.items())
           if (r := _mean_std_row(name, art)) is not None]
    tp = {n: _tput(a) for n, a in arts.items() if _tput(a)}
    if len(tp) >= 2:
        spread = max(tp.values()) / max(min(tp.values()), 1)
        out.append(csv_row("zipf/summary", 0, 1,
                           f"max_over_min_tput={spread:.2f}x across theta "
                           f"(keys never route in Pig: expect ~1.0x)"))
    return out


def _openloop(arts, quick):
    out = []
    sat = {}
    for name, art in arts.items():
        proto = name.split("/")[1]
        rate = (art["spec"].get("workload") or {}).get("rate_hz", 0.0)
        def fmt(p, us, wall, count, proto=proto, rate=rate):
            offered = p["clients"] * rate
            return csv_row(
                f"openloop/{proto}/clients={p['clients']}", wall, count,
                f"offered={offered:.0f}req/s "
                f"achieved={ms(p['throughput']['mean']):.0f}req/s "
                f"median={ms(p['median_ms']['mean']):.2f}ms "
                f"p99={ms(p['p99_ms']['mean']):.2f}ms")
        out.extend(_point_rows(art, fmt))
        sat[proto] = _sat(art)
    if len(sat) >= 2:
        parts = " ".join(f"{p}={t:.0f}" for p, t in sorted(sat.items()))
        out.append(csv_row("openloop/summary", 0, 1,
                           f"open-loop saturation: {parts} req/s"))
    return out


def _wan(arts, quick):
    """WAN at N in {25,49,101}: per-size rows for both backends plus the
    DES<->batch cross-check ratio on the sizes where both ran."""
    out = [r for name, art in sorted(arts.items())
           if (r := _mean_std_row(name, art)) is not None]
    by_n: Dict[str, Dict[str, float]] = {}
    med: Dict[str, Dict[str, float]] = {}
    for name, art in arts.items():
        ntag = name.split("/")[1]
        backend = art.get("backend", "des")
        by_n.setdefault(ntag, {})[backend] = _tput(art)
        m = art["summary"]["median_ms"]["mean"]
        if m is not None:
            med.setdefault(ntag, {})[backend] = m
    for ntag, t in sorted(by_n.items()):
        if {"des", "batch"} <= set(t) and t["des"]:
            mr = (med.get(ntag, {}).get("batch", 0)
                  / max(med.get(ntag, {}).get("des", 1) or 1, 1e-9))
            out.append(csv_row(
                f"wan/{ntag}/xcheck", 0, 1,
                f"batch/des tput={t['batch'] / t['des']:.2f}x "
                f"median={mr:.2f}x (expect ~1.0x both)"))
    return out


def _scale(arts, quick):
    """Batch-backend headroom sweeps: throughput vs the Eq. 1 leader bound
    (1 / (2R+2) c) — the bound the paper's 25-node testbed could not probe."""
    out = []
    for name, art in sorted(arts.items()):
        row = _mean_std_row(name, art)
        if row is None:
            continue
        out.append(row)
        spec = art.get("spec") or {}
        r = (spec.get("pig") or {}).get("n_groups")
        if r and _tput(art):
            from repro.core.messages import CostModel
            bound = 1.0 / (analytical.leader_messages(r) * CostModel.base)
            out.append(csv_row(
                f"{name}/vs_bound", 0, 1,
                f"tput={_tput(art):.0f} = "
                f"{_tput(art) / bound:.2f}x of Eq.1 leader bound "
                f"({bound:.0f} req/s at R={r})"))
    return out


def _conflict(arts, quick):
    """EPaxos conflict sweeps: per-point rows for both backends, the
    conflict-free-relative summary per N, and a DES<->batch xcheck ratio
    per (N, c) where both ran — the fidelity row the regression gate
    bounds to [0.90, 1.10]."""
    out = [r for name, art in sorted(arts.items())
           if (r := _mean_std_row(name, art)) is not None]
    by_n: Dict[tuple, Dict[float, float]] = {}
    for name, art in arts.items():
        parts = name.split("/")
        backend = "batch" if parts[-1] == "batch" else "des"
        ntag, ctag = parts[1], parts[2]
        by_n.setdefault((ntag, backend), {})[float(ctag.split("=")[1])] \
            = _tput(art)
    for (ntag, backend), cs in sorted(by_n.items()):
        if 0.0 in cs and max(cs) > 0.0:
            hi = cs[max(cs)]
            tag = f"{ntag}/batch" if backend == "batch" else ntag
            out.append(csv_row(f"conflict/summary/{tag}", 0, 1,
                               f"tput_at_c={max(cs)}: {hi:.0f}req/s = "
                               f"{hi / max(cs[0.0], 1):.2f}x of conflict-free"))
    for (ntag, backend), cs in sorted(by_n.items()):
        if backend != "des":
            continue
        bs = by_n.get((ntag, "batch"), {})
        for c in sorted(set(cs) & set(bs)):
            if cs[c]:
                out.append(csv_row(
                    f"conflict/{ntag}/c={c}/xcheck", 0, 1,
                    f"batch/des tput={bs[c] / cs[c]:.2f}x "
                    f"(slow-path model: expect within ~0.1 of 1.0)"))
    return out


def _batching(arts, quick):
    """Batching/pipelining family: per-cell rows, the m=8 over m=1 speedup
    per protocol (the gate requires >= 2x for paxos), and the DES<->batch
    fidelity ratio per (protocol, m) where both backends ran."""
    out = [r for name, art in sorted(arts.items())
           if (r := _mean_std_row(name, art)) is not None]
    by_m: Dict[tuple, Dict[int, float]] = {}
    for name, art in arts.items():
        parts = name.split("/")
        if parts[1] == "pipeline":
            continue
        backend = "batch" if parts[-1] == "batch" else "des"
        m = int(parts[2].split("=")[1])
        by_m.setdefault((parts[1], backend), {})[m] = _tput(art)
    for (proto, backend), ms_ in sorted(by_m.items()):
        if backend == "des" and 1 in ms_ and max(ms_) > 1 and ms_[1]:
            top = max(ms_)
            out.append(csv_row(
                f"batching/summary/{proto}", 0, 1,
                f"m={top}_over_m=1 speedup="
                f"{ms_[top] / ms_[1]:.2f}x (gate: paxos >= 2x)"))
    for (proto, backend), ms_ in sorted(by_m.items()):
        if backend != "des":
            continue
        bs = by_m.get((proto, "batch"), {})
        for m in sorted(set(ms_) & set(bs)):
            if ms_[m]:
                out.append(csv_row(
                    f"batching/{proto}/m={m}/xcheck", 0, 1,
                    f"batch/des tput={bs[m] / ms_[m]:.2f}x "
                    f"(saturated-batch model: expect within ~0.1 of 1.0)"))
    return out


def _ovl_points(art) -> List[dict]:
    """Per-clients aggregates of the overload extras (goodput/p99.9/shed
    live per unit, not in the runner's generic point aggregation)."""
    by_clients: Dict[int, List[dict]] = {}
    for u in art["units"]:
        by_clients.setdefault(u["clients"], []).append(u)
    pts = []
    for k, us in sorted(by_clients.items()):
        exs = [u.get("extras") or {} for u in us]
        gp = [e["goodput"] for e in exs if e.get("goodput") is not None]
        p999 = [e["p999_ms"] for e in exs if e.get("p999_ms") is not None]
        adm = [e["admission"] for e in exs if "admission" in e]
        pts.append({
            "clients": k,
            "offered": next((e["offered"] for e in exs
                             if e.get("offered") is not None), None),
            "throughput": (sum(u["throughput"] or 0 for u in us)
                           / max(len(us), 1)),
            "goodput": sum(gp) / len(gp) if gp else None,
            "p99_ms": (sum(u["p99_ms"] or 0 for u in us) / max(len(us), 1)),
            "p999_ms": sum(p999) / len(p999) if p999 else None,
            "client_shed": sum(e.get("client_shed", 0) for e in exs),
            # queue-length policies report shed_queue/shed_rate, the
            # latency-driven policy reports shed_latency — sum whatever ran
            "adm_shed": sum(a.get("shed_queue", 0) + a.get("shed_rate", 0)
                            + a.get("shed_latency", 0) for a in adm),
        })
    return pts


def _overload(arts, quick):
    """Overload family: offered vs achieved vs goodput per grid point, the
    shed counters on both sides of the admission gate, and the headline
    noadm-vs-adm comparison at the top of the load sweep (the claim the
    regression gate turns into a bound: goodput holds flat under 4x
    offered load WITH admission control and collapses without)."""
    out = []
    top: Dict[str, dict] = {}
    for name, art in sorted(arts.items()):
        pts = _ovl_points(art)
        wall = _wall(art)
        for p in pts:
            off = (f"{p['offered']:.0f}req/s" if p["offered"] is not None
                   else "n/a")
            out.append(csv_row(
                f"{name}/clients={p['clients']}", wall / max(len(pts), 1), 1,
                f"offered={off} achieved={p['throughput']:.0f}req/s "
                f"goodput={ms(p['goodput']):.0f}req/s "
                f"p99={ms(p['p99_ms']):.2f}ms p999={ms(p['p999_ms']):.2f}ms "
                f"shed_client={p['client_shed']} shed_adm={p['adm_shed']} "
                f"consistency={_consistency_tag(art)}"))
        if pts:
            top[name] = max(pts, key=lambda p: p["offered"] or 0)
    a, n = top.get("overload/paxos/adm"), top.get("overload/paxos/noadm")
    if a is not None and n is not None:
        out.append(csv_row(
            "overload/summary", 0, 1,
            f"goodput_at_4x adm={ms(a['goodput']):.0f}req/s "
            f"noadm={ms(n['goodput']):.0f}req/s "
            f"(admission holds goodput; without it the SLO collapses)"))
    la = top.get("overload/paxos/latadm")
    if la is not None and a is not None:
        out.append(csv_row(
            "overload/latadm_summary", 0, 1,
            f"goodput_at_4x latency_adm={ms(la['goodput']):.0f}req/s "
            f"queue_adm={ms(a['goodput']):.0f}req/s "
            f"shed latency_adm={la['adm_shed']} queue_adm={a['adm_shed']} "
            f"(head-to-head: SLO-driven shedding vs queue-length shedding)"))
    return out


# ------------------------------------------------------- fault families
def _consistency_tag(art: dict) -> str:
    """Roll the per-unit audit verdicts up to one token for the row."""
    if art.get("consistency") == "model":
        return "model"
    verdicts = {u.get("consistency") for u in art["units"]
                if "consistency" in u}
    if not verdicts:
        return "unchecked"
    return "ok" if verdicts == {"ok"} else "VIOLATION"


def _fault_window(art: dict) -> Optional[tuple]:
    """(first crash t, its recover t) from the artifact's fault timeline."""
    evs = art.get("faults") or []
    down = {}
    for ev in evs:
        if ev[0] == "crash":
            down.setdefault(ev[1], ev[2])
        elif ev[0] == "recover" and ev[1] in down:
            return (down[ev[1]], ev[2])
    return None


def _dip_depth(art: dict, rep: dict) -> Optional[float]:
    """Throughput-dip depth over the fault window, from the completion
    timeline: 1 - (rate during the window / rate before it)."""
    win = _fault_window(art)
    tl = (rep.get("extras") or {}).get("timeline")
    if win is None or tl is None:
        return None
    b = tl["bucket_s"]
    counts = tl["counts"]
    warmup = rep["warmup_s"]
    lo, hi = round(win[0] / b), round(win[1] / b)
    w0 = round(warmup / b)
    if not (w0 < lo < hi <= len(counts)):
        return None
    pre = sum(counts[w0:lo]) / max(lo - w0, 1)
    during = sum(counts[lo:hi]) / max(hi - lo, 1)
    return 1.0 - during / max(pre, 1e-9)


def _avail(arts, quick):
    """Availability family: per-scenario rows (throughput, unavailability
    window, dip depth, audit verdict) plus the DES<->batch dip cross-check
    on the names where both backends ran."""
    out = []
    dips: Dict[str, Dict[str, float]] = {}
    for name, art in sorted(arts.items()):
        rep = _rep(art)
        if rep is None:
            continue
        ex = rep.get("extras") or {}
        dip = _dip_depth(art, rep)
        base = name[:-len("/batch")] if name.endswith("/batch") else name
        if dip is not None:
            dips.setdefault(base, {})[art.get("backend", "des")] = dip
        bits = [f"tput={rep['throughput']:.0f}req/s"]
        if "unavail_ms" in ex:
            bits.append(f"unavail={ms(ex['unavail_ms']):.0f}ms")
        if dip is not None:
            bits.append(f"dip={dip:.2f}")
        if "client_retries" in ex:
            bits.append(f"retries={ex['client_retries']}")
        bits.append(f"consistency={_consistency_tag(art)}")
        out.append(csv_row(name, _wall(art), rep["count"], " ".join(bits)))
    for base, d in sorted(dips.items()):
        if {"des", "batch"} <= set(d):
            # the <~0.1 dip-parity expectation holds for LEADER-crash plans
            # (the deferred-arrival model mirrors the outage exactly);
            # relay-crash dips come from missed fan-outs / catch-up traffic
            # / consumed PRC slack, which the mask model deliberately skips
            leader_fault = any(
                ev[0] == "crash" and ev[1] == 0
                for name, art in arts.items() if name.startswith(base)
                for ev in (art.get("faults") or []))
            note = ("expect <~0.1" if leader_fault else
                    "model boundary: DES authoritative for relay faults")
            out.append(csv_row(
                f"{base}/xcheck", 0, 1,
                f"dip des={d['des']:.2f} batch={d['batch']:.2f} "
                f"delta={abs(d['des'] - d['batch']):.3f} ({note})"))
    return out


def _storm(arts, quick):
    """Storm family: throughput under randomized crash-recover storms with
    the injected-event count and the audit verdict per scenario."""
    out = []
    for name, art in sorted(arts.items()):
        rep = _rep(art)
        if rep is None:
            continue
        ex = rep.get("extras") or {}
        n_ev = len(art.get("faults") or [])
        s = art["summary"]["throughput"]
        out.append(csv_row(
            name, _wall(art), rep["count"],
            f"tput={ms(s['mean']):.0f}req/s std={s['std'] or 0:.0f} "
            f"fault_events={n_ev} "
            f"unavail={ms(ex.get('unavail_ms')):.0f}ms "
            f"retries={ex.get('client_retries', 0)} "
            f"consistency={_consistency_tag(art)}"))
    return out


def _reconfig(arts, quick):
    """Reconfiguration family: throughput under membership change, the
    membership events applied, the unavailability window, and the audit
    verdict (checked against the time-varying membership)."""
    out = []
    for name, art in sorted(arts.items()):
        rep = _rep(art)
        if rep is None:
            continue
        ex = rep.get("extras") or {}
        cfg = [ev for ev in (art.get("faults") or [])
               if ev[0] in ("add_node", "remove_node", "replace_leader")]
        evs = " ".join(f"{ev[0]}({ev[1]})@{ev[2]:.1f}s" for ev in cfg)
        out.append(csv_row(
            name, _wall(art), rep["count"],
            f"tput={rep['throughput']:.0f}req/s events=[{evs}] "
            f"unavail={ms(ex.get('unavail_ms')):.0f}ms "
            f"retries={ex.get('client_retries', 0)} "
            f"consistency={_consistency_tag(art)}"))
    return out


def _rolling(arts, quick):
    """Rolling-upgrade family: every node restarted in sequence; reports
    the per-restart unavailability windows (mean and worst) alongside the
    restart count and the audit verdict."""
    out = []
    for name, art in sorted(arts.items()):
        rep = _rep(art)
        if rep is None:
            continue
        ex = rep.get("extras") or {}
        per = ex.get("per_fault_unavail_ms") or []
        ws = [p["unavail_ms"] for p in per if p["unavail_ms"] is not None]
        bits = [f"tput={rep['throughput']:.0f}req/s",
                f"restarts={len(per)}"]
        if ws:
            bits.append(f"unavail_per_restart_mean="
                        f"{sum(ws) / len(ws):.0f}ms")
            bits.append(f"unavail_per_restart_max={max(ws):.0f}ms")
        bits.append(f"retries={ex.get('client_retries', 0)}")
        bits.append(f"consistency={_consistency_tag(art)}")
        out.append(csv_row(name, _wall(art), rep["count"], " ".join(bits)))
    return out


def _failover(arts, quick):
    """Failover-policy family: the leader dies for good and the external
    detector promotes a successor — per-detect rows plus the sweep summary
    (unavailability should track detect_timeout nearly 1:1)."""
    out = []
    sweep = {}
    for name, art in sorted(arts.items()):
        rep = _rep(art)
        if rep is None:
            continue
        ex = rep.get("extras") or {}
        fo = ex.get("failover_events") or []
        detect = ((art.get("spec") or {}).get("failover") or {}) \
            .get("detect_timeout")
        if detect is not None and ex.get("unavail_ms") is not None:
            sweep[detect * 1e3] = ex["unavail_ms"]
        out.append(csv_row(
            name, _wall(art), rep["count"],
            f"tput={rep['throughput']:.0f}req/s "
            f"unavail={ms(ex.get('unavail_ms')):.0f}ms "
            f"failovers={len(fo)} "
            f"retries={ex.get('client_retries', 0)} "
            f"consistency={_consistency_tag(art)}"))
    if len(sweep) >= 2:
        parts = " ".join(f"{d:.0f}ms->{u:.0f}ms"
                         for d, u in sorted(sweep.items()))
        out.append(csv_row("failover/summary", 0, 1,
                           f"unavail vs detect: {parts} "
                           f"(expect unavail ~= detect + election)"))
    return out


def _gini(vals) -> float:
    """Gini coefficient of a non-negative sample (0 = perfectly even)."""
    vals = sorted(vals)
    n, s = len(vals), sum(vals)
    if n == 0 or s <= 0:
        return 0.0
    cum = sum((i + 1) * v for i, v in enumerate(vals))
    return (2.0 * cum / (n * s)) - (n + 1.0) / n


def _relay_fairness(rep: dict, n: int) -> Optional[dict]:
    """Fairness of follower busy time from the obs section's per-node CPU
    seconds: max/mean (hotspot factor) and Gini over nodes 1..n-1."""
    ob = (rep.get("extras") or {}).get("obs") or {}
    busy = ob.get("cpu_busy_s") or {}
    vals = [float(busy.get(str(i), 0.0)) for i in range(1, n)]
    if not vals or sum(vals) <= 0:
        return None
    mean = sum(vals) / len(vals)
    return {"max_over_mean": max(vals) / mean, "gini": _gini(vals)}


def _obs(arts, quick):
    """Observability family: per-scenario critical-path decomposition (the
    bottleneck attribution rows), tracer volume, batch-side leader-backlog
    series, and the relay-fairness comparison — rotating vs static relays
    on the fig8-style cells, making the paper's 'rotation spreads the relay
    load' claim (Fig. 8 discussion) an empirical number: max/mean and Gini
    of per-follower busy seconds should both be lower with rotation."""
    out = []
    fair = {}
    for name, art in sorted(arts.items()):
        rep = _rep(art)
        if rep is None:
            continue
        ob = (rep.get("extras") or {}).get("obs") or {}
        f = _relay_fairness(rep, (art.get("spec") or {}).get("n", 0))
        if (ob.get("critical_path") or {}).get("n_ops"):
            cp = ob["critical_path"]["mean_ms"]
            seg = " ".join(f"{k}={cp[k]:.2f}" for k in
                           ("queue", "svc", "ser", "relay", "net", "wait")
                           if k in cp)
            tr = ob.get("trace") or {}
            out.append(csv_row(
                name, _wall(art), rep["count"],
                f"tput={rep['throughput']:.0f}req/s "
                f"traced={tr.get('ops_finished', 0)} "
                f"spans={tr.get('spans', 0)} critpath_ms[{seg}]"))
        elif "leader_backlog" in ob:
            lb = ob["leader_backlog"]
            qs = [v for v, c in zip(lb["mean_ms"], lb["n"]) if c]
            mean_q = sum(qs) / len(qs) if qs else 0.0
            out.append(csv_row(
                name, _wall(art), rep["count"],
                f"tput={rep['throughput']:.0f}req/s "
                f"leader_backlog_mean={mean_q:.3f}ms "
                f"peak={max(qs, default=0.0):.3f}ms buckets={len(qs)}"))
        elif f is not None:
            out.append(csv_row(
                name, _wall(art), rep["count"],
                f"tput={rep['throughput']:.0f}req/s "
                f"follower_busy max/mean={f['max_over_mean']:.2f} "
                f"gini={f['gini']:.3f}"))
        elif (row := _mean_std_row(name, art)) is not None:
            out.append(row)
        if f is not None and "/fairness/" in name:
            fair[name.rsplit("/", 1)[1]] = f
    rot, stat = fair.get("rotating"), fair.get("static")
    if rot is not None and stat is not None:
        out.append(csv_row(
            "obs/fairness/summary", 0, 1,
            f"relay busy max/mean rotating={rot['max_over_mean']:.2f} "
            f"static={stat['max_over_mean']:.2f} "
            f"gini rotating={rot['gini']:.3f} static={stat['gini']:.3f} "
            f"(paper Fig8: rotation spreads relay load -> rotating < static)"))
    return out


def _megagrid(arts, quick):
    """Megagrid family: catalog ``megagrid/slice`` scenarios (replicate
    rows) and the million-cell cross-product artifact (aggregate-only
    entries from ``experiments.megagrid``), plus a family summary naming
    the peak-throughput point."""
    out, best, cells = [], None, 0
    for name, art in sorted(arts.items()):
        row = _mean_std_row(name, art)
        if row is not None:                      # catalog slice entries
            out.append(row)
            continue
        s = art.get("summary") or {}
        t = s.get("throughput") or {}
        if t.get("mean") is None:
            continue
        cells += s.get("cells", 0)
        if best is None or t["max"] > best[1]:
            best = (name, t["max"])
        p99 = (s.get("p99_ms") or {}).get("mean")
        out.append(csv_row(
            name, 0, max(s.get("cells", 1), 1),
            f"tput={t['mean']:.0f}req/s std={t['std'] or 0:.0f} "
            f"p99={ms(p99):.2f}ms cells={s.get('cells', 0)}"))
    if best is not None:
        out.append(csv_row("megagrid/summary", 0, 1,
                           f"{cells} cells; peak point {best[0]} "
                           f"at {best[1]:.0f}req/s"))
    return out


def _rw_of(art) -> Optional[dict]:
    rep = _rep(art)
    return (rep.get("extras") or {}).get("rw") if rep else None


def _reads(arts, quick):
    """Read-path family: per-scenario rows with the read/write latency
    split and audit verdict, the leased-vs-log speedup (regression-gated
    at >= 2x), the Pig-vs-Paxos crossover across read ratios, and the
    DES<->batch fidelity ratios on the paired cells (gated [0.90, 1.10])."""
    out = []
    tp = {name: _tput(art) for name, art in arts.items()}
    for name, art in sorted(arts.items()):
        rep = _rep(art)
        if rep is None:
            continue
        rw = _rw_of(art) or {}
        bits = [f"tput={rep['throughput']:.0f}req/s"]
        if rw:
            bits.append(f"reads={rw.get('reads', 0)} "
                        f"read_mean={ms(rw.get('read_mean_ms')):.2f}ms "
                        f"write_mean={ms(rw.get('write_mean_ms')):.2f}ms")
            if rw.get("lease_reads"):
                bits.append(f"lease_reads={rw['lease_reads']}")
        bits.append(f"consistency={_consistency_tag(art)}")
        out.append(csv_row(name, _wall(art), rep["count"], " ".join(bits)))
    # leased reads vs the log read path (the paper's only read path)
    for proto in ("paxos", "pigpaxos"):
        lease = tp.get(f"reads/{proto}/lease/r=0.9")
        log = tp.get(f"reads/{proto}/log/r=0.9")
        if lease and log:
            out.append(csv_row(
                f"reads/speedup/{proto}", 0, 1,
                f"leased/log tput={lease / log:.2f}x at r=0.9 "
                f"(gate: >= 2x — reads skip the whole commit round)"))
    # Pig-vs-Paxos crossover: Pig's relay fan-out wins on writes, but the
    # lease path serves reads at the leader in BOTH protocols, so the gap
    # must close (and invert) as the read ratio rises
    ratios = {}
    for r in ("0.0", "0.5", "0.9"):
        pig, pax = (tp.get(f"reads/pigpaxos/lease/r={r}"),
                    tp.get(f"reads/paxos/lease/r={r}"))
        if pig and pax:
            ratios[r] = pig / pax
    if len(ratios) >= 2:
        parts = " ".join(f"r={r}:{v:.2f}x" for r, v in sorted(ratios.items()))
        lo, hi = min(ratios), max(ratios)
        trend = ("crossover: Pig lead shrinks with read ratio"
                 if ratios[hi] < ratios[lo] else
                 "NO crossover (Pig lead did not shrink)")
        out.append(csv_row("reads/crossover", 0, 1,
                           f"pig/paxos tput {parts} ({trend})"))
    # DES<->batch fidelity on the paired cells
    for name in sorted(arts):
        if not name.endswith("/batch"):
            continue
        base = name[:-len("/batch")]
        if tp.get(base) and tp.get(name):
            out.append(csv_row(
                f"{base}/xcheck", 0, 1,
                f"batch/des tput={tp[name] / tp[base]:.2f}x "
                f"(leased-read model: expect within ~0.1 of 1.0)"))
    return out


def _lease(arts, quick):
    """Lease-expiry family: availability windows across lease durations
    under a leader crash + failover.  Follower lease promises block the
    successor's phase 1 until the old lease drains, so unavail_ms must
    GROW with the lease duration — the safety/availability trade, with
    the read-aware auditor proving no stale read slipped through."""
    out = []
    unavail = {}
    for name, art in sorted(arts.items()):
        rep = _rep(art)
        if rep is None:
            continue
        ex = rep.get("extras") or {}
        rw = _rw_of(art) or {}
        if "unavail_ms" in ex and "d=" in name:
            unavail[name.split("d=")[1]] = ex["unavail_ms"]
        out.append(csv_row(
            name, _wall(art), rep["count"],
            f"tput={rep['throughput']:.0f}req/s "
            f"unavail={ms(ex.get('unavail_ms')):.0f}ms "
            f"retries={ex.get('client_retries', 0)} "
            f"lease_reads={rw.get('lease_reads', 0)} "
            f"consistency={_consistency_tag(art)}"))
    if {"50ms", "400ms"} <= set(unavail):
        ok = unavail["400ms"] > unavail["50ms"]
        out.append(csv_row(
            "lease/expiry/summary", 0, 1,
            f"unavail d=50ms:{unavail['50ms']:.0f}ms "
            f"d=400ms:{unavail['400ms']:.0f}ms — a held lease blocks the "
            f"successor until it drains "
            f"({'window grows with duration, as required' if ok else 'VIOLATION: window did not grow'})"))
    return out


SUMMARIZERS = {
    "table1": _table1, "table2": _table2,
    "fig8": _fig8, "fig9": _fig9, "fig10": _fig10, "fig11": _fig11,
    "fig12": _fig12, "fig13": _fig13, "fig14": _fig14, "fig15": _fig15,
    "fig16": _fig16, "fig17": _fig17,
    "zipf": _zipf, "openloop": _openloop, "conflict": _conflict,
    "wan": _wan, "scale": _scale,
    "batching": _batching, "overload": _overload,
    "avail": _avail, "storm": _storm,
    "reconfig": _reconfig, "rolling": _rolling, "failover": _failover,
    "megagrid": _megagrid, "obs": _obs,
    "reads": _reads, "lease": _lease,
}


def rows_for_artifact(artifact: dict,
                      families: Optional[Sequence[str]] = None) -> List[str]:
    """Legacy CSV rows for the scenario families present in ``artifact``
    (optionally restricted/ordered by ``families``)."""
    by_family: Dict[str, Dict[str, dict]] = {}
    order: List[str] = []
    for sa in artifact["scenarios"]:
        fam = sa["family"]
        if fam not in by_family:
            by_family[fam] = {}
            order.append(fam)
        by_family[fam][sa["name"]] = sa
    out = []
    for fam in (families if families is not None else order):
        if fam in by_family and fam in SUMMARIZERS:
            out.extend(SUMMARIZERS[fam](by_family[fam], artifact["quick"]))
    return out


def family_rows(families: Sequence[str], quick: bool = True,
                processes: int = 0, filter_expr: Optional[str] = None,
                artifact: Optional[dict] = None) -> List[str]:
    """Run the given families through the registry runner (or reuse a
    pre-computed suite ``artifact``) and return their legacy CSV rows."""
    if artifact is None:
        artifact = runner.run_families(families, quick=quick,
                                       processes=processes,
                                       filter_expr=filter_expr)
    return rows_for_artifact(artifact, families)
