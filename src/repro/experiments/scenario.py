"""Declarative experiment specs.

A :class:`Scenario` is pure data: protocol, cluster size, Pig configuration,
topology, workload shape, failure schedule, offered-load grid, and seeds.
The runner (``runner.py``) turns one scenario into ``len(clients) x
len(seeds)`` independent DES runs — the unit of process-level parallelism —
and folds them into one JSON-stable artifact with per-seed replicates.
Scenarios with ``backend="batch"`` instead run their entire grid as ONE
jitted call on the vectorized backend (``repro.core.vectorsim``).

Scenarios are registered in ``registry.py`` (the paper reproductions live in
``catalog.py``); adding a new experiment regime is a ~10-line registry entry,
not a new benchmark script.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

from repro.core import PigConfig, Topology, WorkloadConfig, wan_topology
from repro.faults import FaultPlan
from repro.faults.plan import validate_event

# Legacy failure schedule entries (all times are virtual seconds):
#   ("crash", node_id, t)        — node stops responding at t
#   ("recover", node_id, t)      — node comes back at t
#   ("partition", a, b, t)       — link a<->b cut at t
#   ("heal", a, b, t)            — link restored at t
# Validated at registry time (Scenario.__post_init__) and folded into the
# scenario's FaultPlan; richer plans (gray/slow nodes, drops, asymmetric
# partitions, periodic events, storms) go in ``faults=FaultPlan(...)``.
FailureEvent = Tuple


@dataclass(frozen=True)
class Scenario:
    """One declarative experiment: everything the runner needs, as data."""

    name: str                                # "<family>/<config...>" path
    protocol: str                            # "paxos" | "pigpaxos" | "epaxos"
    n: int
    pig: Optional[PigConfig] = None
    workload: Optional[WorkloadConfig] = None
    topo: Optional[dict] = None              # {"kind": "wan", "nodes_per_region": [...], "oneway_ms": [[...]]}
    failures: Tuple[FailureEvent, ...] = ()
    # declarative fault plan (repro.faults): crash/recover windows, gray
    # nodes, partitions, storms — merged with ``failures`` by fault_plan()
    faults: Optional[FaultPlan] = None
    # run the linearizability auditor on every DES unit (requires history
    # recording; batch units carry consistency="model" instead)
    audit: bool = False
    clients: Tuple[int, ...] = (60,)         # offered-load grid (client counts)
    # "max"   — the paper's max-throughput methodology: per seed, sweep the
    #           grid and keep the best sustained rate (one replicate/seed)
    # "curve" — latency-vs-throughput curves: report every grid point
    grid_mode: str = "max"
    seeds: Tuple[int, ...] = (2,)
    duration: float = 0.6
    warmup: float = 0.3
    engine: str = "exact"                    # "exact" | "fast" | "ref"
    # "des"   — one Cluster run per (clients, seed) unit (pool-parallel)
    # "batch" — the whole clients x seeds grid is ONE jitted vectorsim call
    backend: str = "des"
    # marks scenarios whose model assumptions the batch backend satisfies
    # (closed loop, no failures, no timeline/flight collection) — the runner
    # can switch these to "batch" wholesale via backend_override
    batch_ok: bool = False
    leader_timeout: float = 50e-3
    # spare (initially non-member) nodes available for add_node/replace
    # membership events — DES only; node ids n..n+spare_nodes-1
    spare_nodes: int = 0
    # failover policy kwargs (repro.runtime.FailoverPolicy) armed on every
    # DES unit: {"detect_timeout": s, "check_interval": s, "successor": ...}
    failover: Optional[dict] = None
    # leader-side batching kwargs (repro.core.BatchConfig): {"max_batch": m,
    # "max_delay_ms": ms}.  DES units pass these to the Cluster; batch-backend
    # units map max_batch to vectorsim's batch_m (saturated-batch model, so
    # max_delay_ms is ignored there and clients must divide by max_batch)
    batch: Optional[dict] = None
    # slot pipelining: at most this many uncommitted proposals in flight at
    # the leader (0 = unbounded, the protocol-native default) — DES only
    pipeline_depth: int = 0
    # leader-lease kwargs (repro.core.paxos.LeaseConfig): {"duration_ms": d,
    # "renew_ms": r, "drift_bound": b, "lease_safety": True}.  Arms quorum-
    # granted leader leases on every (pig)paxos node; required for workloads
    # with read_path="lease".  DES units also get per-node clock rate/offset
    # draws (the drift model the lease margin defends against); batch units
    # model an uncontested held lease (see vectorsim's docstring)
    lease: Optional[dict] = None
    # admission-control kwargs armed on every DES unit: queue-length policy
    # (repro.runtime.AdmissionPolicy) {"max_queue": q, "rate_hz": r,
    # "burst": b}, or — when the dict carries an "slo_ms" key — the
    # latency-driven policy (repro.runtime.LatencyAdmissionPolicy)
    # {"slo_ms": ms, "ewma_alpha": a, "check_interval": s, "resume_frac": f}
    admission: Optional[dict] = None
    # observability kwargs (repro.obs.ObsConfig): {"sample_rate": r,
    # "metrics_dt": s, ...}.  DES units get full span tracing + timeline
    # sampling and an "obs" extras section (trace summary, critical-path
    # decomposition, Perfetto events, timelines, per-node busy seconds);
    # batch units get the leader-backlog series only (timelines-only —
    # tracing needs the event-level DES)
    obs: Optional[dict] = None
    collect: Tuple[str, ...] = ()            # extras: "per_node_msgs" | "flight" | "timeline"
    # quick-mode overrides (None -> use the full-mode value / skip nothing)
    quick_clients: Optional[Tuple[int, ...]] = None
    quick_duration: Optional[float] = None
    quick_warmup: Optional[float] = None
    quick_seeds: Optional[Tuple[int, ...]] = None
    quick_skip: bool = False                 # drop entirely in quick mode

    def __post_init__(self):
        if self.backend not in ("des", "batch"):
            raise ValueError(f"unknown backend {self.backend!r}")
        # registry-time validation: a typo'd failure event must fail HERE,
        # not half-way through a suite run (ROADMAP PR 2 follow-up)
        for ev in self.failures:
            validate_event(tuple(ev))
        plan = self.fault_plan()
        if plan is not None:
            # membership events may target spares (ids n..n+spare_nodes-1)
            plan.validate_targets(self.n + self.spare_nodes, self.horizon)
        if self.spare_nodes and self.backend == "batch":
            raise ValueError(
                "batch backend does not support spare_nodes: membership "
                "change needs a time-varying replica set — use the DES")
        if self.failover is not None and self.backend == "batch":
            raise ValueError(
                "batch backend does not support failover policies — "
                "use the DES")
        if self.admission is not None and self.backend == "batch":
            raise ValueError(
                "batch backend does not support admission control — "
                "use the DES")
        if self.pipeline_depth < 0:
            raise ValueError("pipeline_depth must be >= 0")
        if self.pipeline_depth and self.backend == "batch":
            raise ValueError(
                "batch backend pipelines implicitly (Lindley-chain leader "
                "FIFO == unbounded depth); finite pipeline_depth needs the "
                "DES")
        if self.batch is not None:
            m = self.batch.get("max_batch", 1)
            if m < 1:
                raise ValueError("batch.max_batch must be >= 1")
            if self.backend == "batch":
                if self.protocol == "epaxos":
                    raise ValueError("batch-backend batching is group-kernel "
                                     "only — batched EPaxos runs are DES-"
                                     "authoritative")
                bad = [k for k in self.clients if k % m]
                if bad:
                    raise ValueError(
                        f"batch backend requires client counts divisible by "
                        f"max_batch={m}; offending grid points: {bad}")
        if (self.batch is not None or self.pipeline_depth) \
                and self.engine == "ref":
            raise ValueError("batching/pipelining is not supported by the "
                             "verbatim seed stack (engine='ref')")
        if self.obs is not None:
            if self.engine == "ref":
                raise ValueError("observability is not supported by the "
                                 "verbatim seed stack (engine='ref')")
            if self.backend == "batch" and self.protocol == "epaxos":
                raise ValueError("batch-backend observability is group-"
                                 "kernel only (single-leader backlog "
                                 "series) — traced EPaxos runs need the "
                                 "DES")
            # registry-time validation of the knob values themselves
            from repro.obs import ObsConfig
            ObsConfig(**self.obs)
        rr = (self.workload.read_ratio
              if self.workload is not None else None)
        rpath = (self.workload.read_path
                 if self.workload is not None else "log")
        if rr is not None and rr > 0.0 and self.engine == "ref":
            raise ValueError(
                "read_ratio workloads are not supported by the verbatim "
                "seed stack (engine='ref'): the seed client has no read "
                "op kind — use engine='exact' or 'fast'")
        if self.lease is not None:
            # registry-time knob validation (loud, not half-way through a
            # suite run) + structural constraints the Cluster would reject
            from repro.core.paxos import LeaseConfig
            LeaseConfig(**self.lease)
            if self.protocol == "epaxos":
                raise ValueError(
                    "leases are leader-granted; epaxos is leaderless — "
                    "epaxos read scenarios use read_path='quorum'")
            if self.engine == "ref":
                raise ValueError("leases are not supported by the verbatim "
                                 "seed stack (engine='ref')")
        if rpath == "lease" and rr is not None and rr > 0.0 \
                and self.lease is None:
            raise ValueError(
                "read_path='lease' requires lease= (no granted lease, no "
                "local leader reads — set e.g. lease={'duration_ms': 200})")
        if self.backend == "batch" and rr is not None and rr > 0.0:
            if rpath == "quorum":
                raise ValueError(
                    "batch backend models log and leased leader reads "
                    "only; quorum reads (probe / rinse rounds) need the "
                    "DES")
            if rpath == "lease":
                if plan is not None:
                    raise ValueError(
                        "batch leased reads assume the lease is held for "
                        "the whole run — fault plans need the DES")
                if self.batch is not None \
                        and self.batch.get("max_batch", 1) > 1:
                    raise ValueError(
                        "batch leased reads with leader batching are "
                        "DES-authoritative (reads bypass the batch "
                        "buffer)")
        if self.backend == "batch":
            ok_collect = {"per_node_msgs"}
            if plan is not None:
                ok_collect.add("timeline")   # fault runs emit timelines
            bad = [c for c in self.collect if c not in ok_collect]
            if bad:
                raise ValueError(f"batch backend does not support "
                                 f"{bad} collection — use the DES")
            if plan is not None and not plan.mask_expressible(self.horizon):
                raise ValueError(
                    "batch backend supports only mask-expressible fault "
                    "plans (crash/recover windows + whole-run slow nodes) "
                    "— use the DES for this plan")
            if plan is not None and self.protocol == "epaxos":
                raise ValueError("batch EPaxos does not support faults")

    @property
    def family(self) -> str:
        return self.name.split("/", 1)[0]

    @property
    def horizon(self) -> float:
        """Virtual-time span fault plans are materialized over (the full-mode
        measure window plus the drain)."""
        return self.warmup + self.duration + 0.5

    def fault_plan(self) -> Optional[FaultPlan]:
        """The unified fault plan: ``faults`` merged with the legacy
        ``failures`` tuples.  None when the scenario is fault-free."""
        plan = self.faults
        if self.failures:
            plan = (plan or FaultPlan()) + FaultPlan(
                events=tuple(tuple(ev) for ev in self.failures))
        return plan if plan else None

    def resolve(self, quick: bool) -> "ResolvedScenario":
        if quick:
            return ResolvedScenario(
                scenario=self,
                clients=self.quick_clients or self.clients,
                seeds=self.quick_seeds or self.seeds,
                duration=self.quick_duration or self.duration,
                warmup=self.quick_warmup if self.quick_warmup is not None
                else self.warmup)
        return ResolvedScenario(scenario=self, clients=self.clients,
                                seeds=self.seeds, duration=self.duration,
                                warmup=self.warmup)

    def build_topology(self) -> Optional[Topology]:
        return build_topology(self.topo)

    def spec_dict(self) -> dict:
        """JSON-ready copy of the full spec (recorded in the artifact)."""
        d = dataclasses.asdict(self)
        return _jsonify(d)


@dataclass(frozen=True)
class ResolvedScenario:
    """A scenario with quick/full knobs applied — what the runner executes."""
    scenario: Scenario
    clients: Tuple[int, ...]
    seeds: Tuple[int, ...]
    duration: float
    warmup: float

    def units(self):
        """The independent work units: one DES run per (clients, seed)."""
        for k in self.clients:
            for s in self.seeds:
                yield (k, s)


def build_topology(spec: Optional[dict]) -> Optional[Topology]:
    """Materialize a declarative topology spec (kept as a plain dict so
    scenarios stay picklable and JSON-serializable)."""
    if spec is None:
        return None
    kind = spec.get("kind", "lan")
    if kind == "wan":
        return wan_topology(list(spec["nodes_per_region"]),
                            [list(r) for r in spec["oneway_ms"]])
    if kind == "lan":
        kw = {k: spec[k] for k in ("base_latency", "jitter") if k in spec}
        return Topology(n=spec["n"], **kw)
    raise ValueError(f"unknown topology kind {kind!r}")


def _jsonify(x):
    if isinstance(x, dict):
        return {k: _jsonify(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonify(v) for v in x]
    if isinstance(x, bytes):
        return len(x)            # payload bytes: record the size only
    if isinstance(x, float) and math.isinf(x):
        return None              # open-ended fault windows: strict JSON
    return x
