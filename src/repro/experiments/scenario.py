"""Declarative experiment specs.

A :class:`Scenario` is pure data: protocol, cluster size, Pig configuration,
topology, workload shape, failure schedule, offered-load grid, and seeds.
The runner (``runner.py``) turns one scenario into ``len(clients) x
len(seeds)`` independent DES runs — the unit of process-level parallelism —
and folds them into one JSON-stable artifact with per-seed replicates.
Scenarios with ``backend="batch"`` instead run their entire grid as ONE
jitted call on the vectorized backend (``repro.core.vectorsim``).

Scenarios are registered in ``registry.py`` (the paper reproductions live in
``catalog.py``); adding a new experiment regime is a ~10-line registry entry,
not a new benchmark script.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

from repro.core import PigConfig, Topology, WorkloadConfig, wan_topology

# Failure schedule entries (all times are virtual seconds):
#   ("crash", node_id, t)        — node stops responding at t
#   ("recover", node_id, t)      — node comes back at t
#   ("partition", a, b, t)       — link a<->b cut at t
FailureEvent = Tuple


@dataclass(frozen=True)
class Scenario:
    """One declarative experiment: everything the runner needs, as data."""

    name: str                                # "<family>/<config...>" path
    protocol: str                            # "paxos" | "pigpaxos" | "epaxos"
    n: int
    pig: Optional[PigConfig] = None
    workload: Optional[WorkloadConfig] = None
    topo: Optional[dict] = None              # {"kind": "wan", "nodes_per_region": [...], "oneway_ms": [[...]]}
    failures: Tuple[FailureEvent, ...] = ()
    clients: Tuple[int, ...] = (60,)         # offered-load grid (client counts)
    # "max"   — the paper's max-throughput methodology: per seed, sweep the
    #           grid and keep the best sustained rate (one replicate/seed)
    # "curve" — latency-vs-throughput curves: report every grid point
    grid_mode: str = "max"
    seeds: Tuple[int, ...] = (2,)
    duration: float = 0.6
    warmup: float = 0.3
    engine: str = "exact"                    # "exact" | "fast" | "ref"
    # "des"   — one Cluster run per (clients, seed) unit (pool-parallel)
    # "batch" — the whole clients x seeds grid is ONE jitted vectorsim call
    backend: str = "des"
    # marks scenarios whose model assumptions the batch backend satisfies
    # (closed loop, no failures, no timeline/flight collection) — the runner
    # can switch these to "batch" wholesale via backend_override
    batch_ok: bool = False
    leader_timeout: float = 50e-3
    collect: Tuple[str, ...] = ()            # extras: "per_node_msgs" | "flight" | "timeline"
    # quick-mode overrides (None -> use the full-mode value / skip nothing)
    quick_clients: Optional[Tuple[int, ...]] = None
    quick_duration: Optional[float] = None
    quick_warmup: Optional[float] = None
    quick_seeds: Optional[Tuple[int, ...]] = None
    quick_skip: bool = False                 # drop entirely in quick mode

    def __post_init__(self):
        if self.backend not in ("des", "batch"):
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.backend == "batch":
            bad = [c for c in self.collect if c != "per_node_msgs"]
            if bad or self.failures:
                raise ValueError(
                    "batch backend supports neither failure schedules nor "
                    f"{bad or 'timeline/flight'} collection — use the DES")

    @property
    def family(self) -> str:
        return self.name.split("/", 1)[0]

    def resolve(self, quick: bool) -> "ResolvedScenario":
        if quick:
            return ResolvedScenario(
                scenario=self,
                clients=self.quick_clients or self.clients,
                seeds=self.quick_seeds or self.seeds,
                duration=self.quick_duration or self.duration,
                warmup=self.quick_warmup if self.quick_warmup is not None
                else self.warmup)
        return ResolvedScenario(scenario=self, clients=self.clients,
                                seeds=self.seeds, duration=self.duration,
                                warmup=self.warmup)

    def build_topology(self) -> Optional[Topology]:
        return build_topology(self.topo)

    def spec_dict(self) -> dict:
        """JSON-ready copy of the full spec (recorded in the artifact)."""
        d = dataclasses.asdict(self)
        return _jsonify(d)


@dataclass(frozen=True)
class ResolvedScenario:
    """A scenario with quick/full knobs applied — what the runner executes."""
    scenario: Scenario
    clients: Tuple[int, ...]
    seeds: Tuple[int, ...]
    duration: float
    warmup: float

    def units(self):
        """The independent work units: one DES run per (clients, seed)."""
        for k in self.clients:
            for s in self.seeds:
                yield (k, s)


def build_topology(spec: Optional[dict]) -> Optional[Topology]:
    """Materialize a declarative topology spec (kept as a plain dict so
    scenarios stay picklable and JSON-serializable)."""
    if spec is None:
        return None
    kind = spec.get("kind", "lan")
    if kind == "wan":
        return wan_topology(list(spec["nodes_per_region"]),
                            [list(r) for r in spec["oneway_ms"]])
    if kind == "lan":
        kw = {k: spec[k] for k in ("base_latency", "jitter") if k in spec}
        return Topology(n=spec["n"], **kw)
    raise ValueError(f"unknown topology kind {kind!r}")


def _jsonify(x):
    if isinstance(x, dict):
        return {k: _jsonify(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonify(v) for v in x]
    if isinstance(x, bytes):
        return len(x)            # payload bytes: record the size only
    return x
