"""Deterministic synthetic data pipeline.

Produces a reproducible token stream (per-step, per-host slice) so training
is bitwise restartable from a (step, seed) pair — the property the
checkpoint/restart machinery relies on.  Structure mimics a production
loader: host-sharded batches, background prefetch, and ShapeDtypeStruct
specs for the dry-run.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


class SyntheticLMStream:
    """Markov-ish synthetic token stream: deterministic in (seed, step).
    Yields host-local batches; labels are next-token shifted inputs."""

    def __init__(self, cfg: ModelConfig, data: DataConfig, prefetch: int = 2):
        self.cfg = cfg
        self.data = data
        assert data.global_batch % data.n_hosts == 0
        self.host_batch = data.global_batch // data.n_hosts
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._step = 0
        self._thread: Optional[threading.Thread] = None

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.data.seed * 1_000_003 + step) * 4096 + self.data.host_id)
        B, S, V = self.host_batch, self.data.seq_len, self.cfg.vocab
        # cheap structured stream: random walk over the vocab, so the LM loss
        # is learnable (tests assert loss decreases)
        start = rng.integers(0, V, size=(B, 1))
        steps = rng.integers(-3, 4, size=(B, S))
        toks = (start + np.cumsum(steps, axis=1)) % V
        toks = toks.astype(np.int32)
        labels = np.concatenate([toks[:, 1:], np.full((B, 1), -1, np.int32)],
                                axis=1)
        if self.cfg.frontend:
            emb_rng = np.random.default_rng(self.data.seed * 7 + step)
            emb = emb_rng.standard_normal((B, S, self.cfg.d_model)).astype(np.float32) * 0.1
            return {"embeds": jnp.asarray(emb, jnp.bfloat16),
                    "labels": jnp.asarray(labels)}
        return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}

    # ------------------------------------------------------------ prefetch
    def _worker(self) -> None:
        step = self._step
        while not self._stop.is_set():
            batch = self.batch_at(step)
            self._q.put((step, batch))
            step += 1

    def start(self, step: int = 0) -> None:
        self._step = step
        self._stop.clear()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass

    def __iter__(self) -> Iterator:
        while True:
            yield self._q.get()


def make_batch_specs(cfg: ModelConfig, global_batch: int, seq_len: int) -> dict:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    if cfg.frontend:
        return {
            "embeds": jax.ShapeDtypeStruct((global_batch, seq_len, cfg.d_model),
                                           jnp.bfloat16),
            "labels": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
    }
