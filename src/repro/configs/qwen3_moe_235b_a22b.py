"""qwen3-moe-235b-a22b [moe]: 128 routed experts, top-8, no shared experts.
94L d_model=4096 64H (GQA kv=4) expert d_ff=1536 vocab=151936
[hf:Qwen/Qwen3-30B-A3B; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab=151936,
    n_experts=128, n_shared_experts=0, top_k=8, moe_d_ff=1536,
    rope_theta=1000000.0,
)

SMOKE = CONFIG.replace(name="qwen3-moe-smoke", n_layers=3, d_model=128,
                       n_heads=8, n_kv_heads=2, d_ff=128, vocab=512,
                       n_experts=16, top_k=4, moe_d_ff=128,
                       capacity_factor=8.0)   # dropless in smoke tests
