"""gemma-7b [dense]: GeGLU activation, head_dim=256 (> d_model/n_heads),
tied embeddings.  28L d_model=3072 16H (GQA kv=16) d_ff=24576 vocab=256000
[arXiv:2403.08295; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16,
    head_dim=256, d_ff=24576, vocab=256000, mlp_act="gelu",
    tie_embeddings=True, rope_theta=10000.0,
)

SMOKE = CONFIG.replace(name="gemma-smoke", n_layers=2, d_model=128,
                       n_heads=4, n_kv_heads=4, head_dim=64, d_ff=256, vocab=512)
