"""h2o-danube-1.8b [dense]: llama+mistral mix with sliding-window attention.
24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000 [arXiv:2401.16818; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=6912, vocab=32000, sliding_window=4096, rope_theta=10000.0,
)

SMOKE = CONFIG.replace(name="h2o-danube-smoke", n_layers=2, d_model=128,
                       n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
                       sliding_window=32)
