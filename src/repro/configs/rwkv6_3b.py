"""rwkv6-3b "Finch" [ssm/attention-free]: data-dependent per-channel decay.
32L d_model=2560 d_ff=8960 vocab=65536 [arXiv:2404.05892; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="rwkv",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=8960, vocab=65536, ssm_heads=40,
)

SMOKE = CONFIG.replace(name="rwkv6-smoke", n_layers=2, d_model=128,
                       n_heads=2, n_kv_heads=2, d_ff=256, vocab=512,
                       ssm_heads=2)
