"""Architecture registry: one module per assigned architecture.

Each module defines CONFIG (the exact published configuration) and
SMOKE (a reduced same-family config for CPU smoke tests).
"""
from __future__ import annotations

import importlib
from typing import Dict

from ..models.config import ModelConfig

ARCHS = [
    "h2o_danube_1_8b",
    "qwen2_5_32b",
    "gemma_7b",
    "granite_8b",
    "qwen2_moe_a2_7b",
    "qwen3_moe_235b_a22b",
    "zamba2_7b",
    "rwkv6_3b",
    "internvl2_76b",
    "musicgen_large",
]

# canonical --arch ids (dashes, as listed in the assignment)
ARCH_IDS = [a.replace("_", "-").replace("-1-8b", "-1.8b").replace("-2-5-", "-2.5-")
            .replace("-a2-7b", "-a2.7b") for a in ARCHS]


def _mod(name: str):
    return importlib.import_module(f".{name}", __package__)


def canon(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    return _mod(canon(arch)).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _mod(canon(arch)).SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}
