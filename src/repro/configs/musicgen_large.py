"""musicgen-large [audio]: decoder-only over EnCodec tokens (stub frontend).
48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048 [arXiv:2306.05284; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=2048, frontend="audio", mlp_act="gelu",
)

SMOKE = CONFIG.replace(name="musicgen-smoke", n_layers=2, d_model=128,
                       n_heads=4, n_kv_heads=4, d_ff=256, vocab=256)
