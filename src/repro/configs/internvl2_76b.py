"""internvl2-76b [vlm]: InternViT (stub frontend) + LLM backbone.
80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256
[arXiv:2404.16821; unverified]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, frontend="vision", rope_theta=500000.0,
)

SMOKE = CONFIG.replace(name="internvl2-smoke", n_layers=2, d_model=128,
                       n_heads=4, n_kv_heads=2, d_ff=256, vocab=512)
