"""qwen2-moe-a2.7b [moe]: 4 shared + 60 routed experts, top-4.
24L d_model=2048 16H (GQA kv=16) expert d_ff=1408 vocab=151936
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=151936,
    n_experts=60, n_shared_experts=4, top_k=4, moe_d_ff=1408,
    rope_theta=1000000.0,
)

SMOKE = CONFIG.replace(name="qwen2-moe-smoke", n_layers=2, d_model=128,
                       n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
                       n_experts=8, n_shared_experts=2, top_k=2, moe_d_ff=128,
                       capacity_factor=8.0)   # dropless in smoke tests
