"""zamba2-7b [hybrid]: Mamba2 backbone + one shared attention(+MLP) block
applied every 6 layers.  81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000
ssm_state=64 [arXiv:2411.15242; unverified]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000, ssm_state=64, attn_every=6,
    rope_theta=10000.0,
)

SMOKE = CONFIG.replace(name="zamba2-smoke", n_layers=5, d_model=128,
                       n_heads=4, n_kv_heads=4, d_ff=256, vocab=512,
                       ssm_state=16, attn_every=2, ssm_heads=4)
