"""Checkpointing with consensus-committed manifests.

Layout: one .npy per pytree leaf under <dir>/step_<n>/, plus manifest.json.
A checkpoint only *counts* once its manifest is committed through the
PigPaxos coordination plane ('ckpt/latest'); a crash mid-write leaves a
half-written directory that restore() never looks at — the classic
write-then-commit pattern, with the commit being a real consensus op.

Saves can run asynchronously (background thread over host copies) so the
training loop only blocks for the device->host transfer.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Optional

import jax
import numpy as np

from ..runtime.coordination import CoordinationService


def _flatten_with_names(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "name", k)))
                        for k in path)
        out.append((name, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str,
                 coord: Optional[CoordinationService] = None,
                 async_save: bool = True):
        self.dir = directory
        self.coord = coord
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, state) -> None:
        self.wait()                      # one outstanding save at a time
        host = [(n, np.asarray(jax.device_get(l)))
                for n, l in _flatten_with_names(state)]

        def _write():
            d = os.path.join(self.dir, f"step_{step}")
            os.makedirs(d, exist_ok=True)
            files = {}
            for i, (name, arr) in enumerate(host):
                fn = f"leaf_{i}.npy"
                dt = str(arr.dtype)
                # ml_dtypes (bfloat16 etc.) don't round-trip through .npy:
                # store the raw bits and record the logical dtype
                towrite = arr.view(np.uint16) if dt == "bfloat16" else arr
                np.save(os.path.join(d, fn), towrite, allow_pickle=False)
                files[name] = {"file": fn, "shape": list(arr.shape),
                               "dtype": dt}
            manifest = {"step": step, "dir": f"step_{step}", "files": files}
            with open(os.path.join(d, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            # durable only once consensus-committed:
            if self.coord is not None:
                self.coord.put("ckpt/latest", {"step": step,
                                               "dir": f"step_{step}"})

        if self.async_save:
            self._pending = threading.Thread(target=_write, daemon=True)
            self._pending.start()
        else:
            _write()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        if self.coord is not None:
            meta = self.coord.get("ckpt/latest")
            return None if meta is None else meta["step"]
        steps = [int(d.split("_")[1]) for d in os.listdir(self.dir)
                 if d.startswith("step_")
                 and os.path.exists(os.path.join(self.dir, d, "manifest.json"))]
        return max(steps) if steps else None

    def restore(self, like, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``like``; optionally device_put with
        new shardings (elastic re-shard: the host arrays are mesh-agnostic)."""
        step = self.latest_step() if step is None else step
        if step is None:
            return None
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        names = [n for n, _ in _flatten_with_names(like)]
        leaves = []
        for name in names:
            info = manifest["files"][name]
            arr = np.load(os.path.join(d, info["file"]), allow_pickle=False)
            if info["dtype"] == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            leaves.append(arr)
        treedef = jax.tree_util.tree_structure(like)
        restored = jax.tree_util.tree_unflatten(treedef, leaves)
        like_leaves = jax.tree.leaves(like)
        restored = jax.tree_util.tree_unflatten(
            treedef, [np.asarray(r).astype(l.dtype) if hasattr(l, "dtype") else r
                      for r, l in zip(jax.tree.leaves(restored), like_leaves)])
        if shardings is not None:
            restored = jax.device_put(restored, shardings)
        else:
            restored = jax.tree.map(jax.numpy.asarray, restored)
        return restored, step
