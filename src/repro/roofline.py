"""Roofline accounting from compiled HLO (no hardware required).

Three terms per (arch x shape x mesh), per the assignment:
  compute    = HLO_FLOPs / (chips * peak_FLOPs)
  memory     = HLO_bytes / (chips * HBM_bw)
  collective = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``; collective
bytes are parsed out of the optimized HLO text (operand bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute),
split into in-pod (ICI) and cross-pod (DCN) traffic via replica_groups.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

# -------------------------------------------------------- TPU v5e constants
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~3 links/chip on a 2D torus)
DCN_BW = 25e9                # bytes/s per chip across pods (conservative)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape string like 'f32[2,1031]' (tuples: sum parts)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_GROUPS_BRACES = re.compile(r"replica_groups=\{(\{[^=]*?\})\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")


def _groups_span_pods(attr_region: str, pod_size: int) -> Optional[bool]:
    """Do any replica groups cross a pod boundary?  None if no groups found."""
    m = _GROUPS_IOTA.search(attr_region)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        domain = [int(d) for d in m.group(3).split(",")]
        n = int(np.prod(domain))
        ids = np.arange(n).reshape(domain)
        if m.group(4):
            perm = [int(p) for p in m.group(4).split(",")]
            ids = ids.transpose(perm)
        groups = ids.reshape(g, s)
        return bool((groups // pod_size != groups[:, :1] // pod_size).any())
    m = _GROUPS_BRACES.search(attr_region)
    if m:
        span = False
        for grp in re.findall(r"\{([\d,]+)\}", m.group(0)):
            mem = np.array([int(x) for x in grp.split(",")])
            if (mem // pod_size != mem[0] // pod_size).any():
                span = True
        return span
    return None


def collective_stats(hlo_text: str, pod_size: Optional[int] = None) -> dict:
    """Parse collective ops: returns {'by_kind': {kind: bytes},
    'total': bytes, 'cross_pod': bytes, 'in_pod': bytes, 'count': int}."""
    by_kind: Dict[str, int] = {}
    cross = 0
    in_pod = 0
    count = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w-]+)", ls)
        if not m:
            continue
        kind = m.group(2)
        if kind.endswith("-start"):
            kind = kind[:-6]
        if kind not in _COLLECTIVES:
            continue
        b = _shape_bytes(m.group(1))
        by_kind[kind] = by_kind.get(kind, 0) + b
        count += 1
        if pod_size:
            span = _groups_span_pods(ls, pod_size)
            if span:
                cross += b
            else:
                in_pod += b
    return {"by_kind": by_kind, "total": sum(by_kind.values()),
            "cross_pod": cross, "in_pod": in_pod, "count": count}


def collective_bytes_by_kind(hlo_text: str) -> Dict[str, int]:
    return collective_stats(hlo_text)["by_kind"]


# ---------------------------------------------------------------------------
# Loop-corrected whole-program analysis.
#
# XLA's HloCostAnalysis visits while-loop bodies ONCE (verified empirically:
# a 10-iteration scan of a matmul reports 1x its flops), so cost_analysis()
# underestimates scan-over-layers models by ~n_layers.  We therefore walk the
# optimized HLO ourselves: multiply every computation's cost by the product
# of enclosing known_trip_count values, count dot flops exactly (output numel
# x contracted dims), and estimate HBM traffic as operand+output bytes of
# every top-level (post-fusion) instruction.
# ---------------------------------------------------------------------------

_INSTR_HEAD_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"([\w\-]+)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_APPLY_RE = re.compile(r"to_apply=%([\w.\-]+)")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")

_NO_TRAFFIC_OPS = {"bitcast", "tuple", "get-tuple-element", "parameter",
                   "constant", "after-all", "iota", "partition-id",
                   "replica-id", "copy-done", "all-gather-done",
                   "all-reduce-done", "collective-permute-done",
                   # control-flow carriers: loop state stays in place
                   "while", "call", "conditional"}


def _split_computations(txt: str):
    comps: Dict[str, list] = {}
    entry = None
    cur = None
    for raw in txt.splitlines():
        line = raw.rstrip()
        if line.endswith("{") and ("(" in line) and ("->" in line) \
                and not raw.startswith("  "):
            header = line.strip()
            is_entry = header.startswith("ENTRY")
            name = header.split("(")[0].replace("ENTRY", "").strip().lstrip("%").strip()
            cur = name
            comps[cur] = []
            if is_entry:
                entry = name
        elif line.strip() == "}":
            cur = None
        elif cur is not None:
            comps[cur].append(line)
    return comps, entry


def _parse_instr(ln: str):
    """Parse '  %name = SHAPE opcode(...)' with balanced tuple shapes."""
    m = _INSTR_HEAD_RE.match(ln)
    if not m:
        return None
    name = m.group(1)
    rest = ln[m.end():]
    if rest.startswith("("):
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        shape = rest[:end + 1]
        rest2 = rest[end + 1:].lstrip()
    else:
        sp = rest.split(None, 1)
        shape = sp[0]
        rest2 = sp[1] if len(sp) > 1 else ""
    m2 = _OPCODE_RE.match(rest2)
    if not m2:
        return None
    return name, shape, m2.group(1), ln


def _operand_section(line: str, opcode: str) -> str:
    i = line.find(opcode + "(")
    if i < 0:
        return ""
    j = i + len(opcode) + 1
    depth = 1
    k = j
    while k < len(line) and depth:
        if line[k] == "(":
            depth += 1
        elif line[k] == ")":
            depth -= 1
        k += 1
    return line[j:k - 1]


def analyze_hlo(txt: str, pod_size: Optional[int] = None) -> dict:
    """Loop-corrected per-device flops / traffic / collective bytes."""
    comps, entry = _split_computations(txt)
    if entry is None:
        return {"flops": 0.0, "traffic_bytes": 0.0, "coll_total": 0.0,
                "coll_cross_pod": 0.0, "coll_in_pod": 0.0, "by_kind": {},
                "loops": []}

    # instruction name -> output shape string (module-wide unique names)
    shape_of: Dict[str, str] = {}
    parsed: Dict[str, list] = {}
    for cname, lines in comps.items():
        plist = []
        for ln in lines:
            p = _parse_instr(ln)
            if p is None:
                continue
            name, shape, opcode, _ = p
            shape_of[name] = shape
            plist.append((name, shape, opcode, ln))
        parsed[cname] = plist

    # multiplier propagation: ENTRY=1; while bodies x trip; call/cond inline
    from collections import defaultdict, deque
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    q = deque([entry])
    loops = []
    seen_edges = set()
    while q:
        c = q.popleft()
        m = mult[c]
        for (name, shape, opcode, ln) in parsed.get(c, []):
            if opcode == "while":
                t = _TRIP_RE.search(ln)
                trip = int(t.group(1)) if t else 1
                loops.append({"comp": c, "trip": trip})
                for rex in (_BODY_RE, _COND_RE):
                    mm = rex.search(ln)
                    if mm and (c, mm.group(1), name) not in seen_edges:
                        seen_edges.add((c, mm.group(1), name))
                        mult[mm.group(1)] += m * trip
                        q.append(mm.group(1))
            elif opcode in ("call", "conditional", "async-start"):
                mm = _APPLY_RE.search(ln)
                if mm and (c, mm.group(1), name) not in seen_edges:
                    seen_edges.add((c, mm.group(1), name))
                    mult[mm.group(1)] += m
                    q.append(mm.group(1))

    flops = 0.0
    traffic = 0.0
    coll_total = 0.0
    coll_cross = 0.0
    coll_in = 0.0
    by_kind: Dict[str, float] = {}
    for cname, m in list(mult.items()):
        for (name, shape, opcode, ln) in parsed.get(cname, []):
            if opcode in _NO_TRAFFIC_OPS:
                continue
            out_b = _shape_bytes(shape)
            opsec = _operand_section(ln, opcode)
            ops_names = _OPERAND_NAME_RE.findall(opsec)
            # opcode-aware traffic: slicing ops touch only the slice, not the
            # (possibly stacked-over-layers) source buffer; updates are
            # in-place
            if opcode in ("dynamic-slice", "gather", "slice"):
                in_b = out_b
            elif opcode == "dynamic-update-slice":
                upd = _shape_bytes(shape_of.get(ops_names[1], "")) \
                    if len(ops_names) > 1 else out_b
                in_b, out_b = upd, upd
            elif opcode == "scatter":
                upd = _shape_bytes(shape_of.get(ops_names[2], "")) \
                    if len(ops_names) > 2 else out_b
                in_b, out_b = 2 * upd, upd
            elif opcode == "fusion" and ("dynamic_update_slice" in ln
                                         or "dynamic-update-slice" in ln):
                # scan-stacking fusion: the big buffer is updated in place;
                # traffic ~ the slice (all operands except the aliased buffer)
                sizes = sorted(_shape_bytes(shape_of.get(o, ""))
                               for o in ops_names)
                in_b = sum(sizes[:-1]) if len(sizes) > 1 else out_b
                out_b = in_b
            elif opcode == "fusion" and ("dynamic_slice" in ln
                                         or "dynamic-slice" in ln):
                sizes = [_shape_bytes(shape_of.get(o, "")) for o in ops_names]
                in_b = min(sum(sizes), 2 * out_b)
                in_b = min(in_b, out_b + sum(s for s in sizes
                                             if s < max(sizes, default=0)))
            else:
                in_b = sum(_shape_bytes(shape_of.get(o, ""))
                           for o in ops_names)
            traffic += m * (out_b + in_b)
            if opcode == "dot":
                dims = _SHAPE_RE.search(shape)
                out_n = 1
                if dims and dims.group(2):
                    for d in dims.group(2).split(","):
                        out_n *= int(d)
                ops = _OPERAND_NAME_RE.findall(opsec)
                cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ln)
                if ops and cd is not None:
                    lhs_shape = shape_of.get(ops[0], "")
                    lm = _SHAPE_RE.search(lhs_shape)
                    if lm and lm.group(2):
                        ldims = [int(d) for d in lm.group(2).split(",")]
                        k = 1
                        for ci in (cd.group(1).split(",") if cd.group(1) else []):
                            k *= ldims[int(ci)]
                        flops += m * 2.0 * out_n * k
            kind = opcode[:-6] if opcode.endswith("-start") else opcode
            if kind in _COLLECTIVES:
                b = out_b if kind in ("all-gather", "all-reduce") else \
                    max(out_b, in_b)
                by_kind[kind] = by_kind.get(kind, 0.0) + m * b
                coll_total += m * b
                if pod_size:
                    span = _groups_span_pods(ln, pod_size)
                    if span:
                        coll_cross += m * b
                    else:
                        coll_in += m * b
    return {"flops": flops, "traffic_bytes": traffic, "coll_total": coll_total,
            "coll_cross_pod": coll_cross, "coll_in_pod": coll_in,
            "by_kind": by_kind, "loops": loops}


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_cross_pod: float
    model_flops: float
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    link_bw: float = ICI_BW

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * self.peak_flops)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * self.hbm_bw)

    @property
    def t_collective(self) -> float:
        """In-pod bytes at ICI bandwidth + cross-pod bytes at DCN bandwidth
        (the scarce resource the Pig schedule protects)."""
        in_pod = self.coll_bytes - self.coll_cross_pod
        return (in_pod / (self.chips * self.link_bw)
                + self.coll_cross_pod / (self.chips * DCN_BW))

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / max(self.hlo_flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """How close the step is to the compute roofline: T_compute / T_bound
        where T_bound = max of the three terms (1.0 = compute-bound at peak)."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        return self.t_compute / t if t > 0 else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips, "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes, "coll_bytes": self.coll_bytes,
            "coll_cross_pod": self.coll_cross_pod,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_train(param_count: int, tokens: int) -> float:
    """6*N*D for a training step (fwd+bwd)."""
    return 6.0 * param_count * tokens


def model_flops_decode(active_params: int, tokens: int) -> float:
    """2*N*D for a forward-only decode step."""
    return 2.0 * active_params * tokens
